"""Paper Fig. 7 (+ Fig. 16): end-to-end NVRAR-vs-NCCL speedup for
decode-heavy batched inference across models and GPU counts, plus a REAL
numerical end-to-end run: the tiny engine generating with flat vs
hierarchical all-reduce strategies produces identical tokens (correctness of
the integration the speedups rely on)."""
from __future__ import annotations

from .common import emit


def simulated():
    from repro.inference.simulator import simulate_batch_latency, A100, GH200
    from repro.core.comm_model import PERLMUTTER, VISTA
    from repro.configs.llama3_paper import LLAMA31_70B, LLAMA31_405B

    for model, gpus in ((LLAMA31_70B, (8, 16, 32)),
                        (LLAMA31_405B, (32, 64, 128))):
        for npr in (8, 32):
            for n in gpus:
                t_n, _ = simulate_batch_latency(
                    model, A100, PERLMUTTER, n, scheme="tp",
                    ar_algo="nccl", prompt_len=1426, decode_len=3072,
                    n_prompts=npr)
                t_v, _ = simulate_batch_latency(
                    model, A100, PERLMUTTER, n, scheme="tp",
                    ar_algo="nvrar", prompt_len=1426, decode_len=3072,
                    n_prompts=npr)
                emit(f"fig7/{model.name}/P{npr}/gpus{n}", t_v * 1e6,
                     f"nccl_s={t_n:.1f};speedup={t_n/t_v:.2f}x")
    # Vista (Fig. 16): 1 GPU/node
    for n in (4, 8, 16):
        t_n, _ = simulate_batch_latency(
            LLAMA31_70B, GH200, VISTA, n, scheme="tp", ar_algo="nccl",
            prompt_len=1426, decode_len=3072, n_prompts=32)
        t_v, _ = simulate_batch_latency(
            LLAMA31_70B, GH200, VISTA, n, scheme="tp", ar_algo="nvrar",
            prompt_len=1426, decode_len=3072, n_prompts=32)
        emit(f"fig16/vista/llama70b/P32/gpus{n}", t_v * 1e6,
             f"nccl_s={t_n:.1f};speedup={t_n/t_v:.2f}x")


def real_integration():
    """Numerical equivalence of the AR strategies inside a real generate()
    loop (8 simulated devices; run via the dist harness when available)."""
    import jax
    if len(jax.devices()) < 8:
        emit("fig7/real_integration", 0.0, "skipped=needs_8_devices")
        return
    import numpy as np
    import jax.numpy as jnp
    from repro.core.compat import AxisType, make_mesh
    from repro.core.pcontext import ParallelCtx
    from repro.models import ModelConfig, make_plan, init_params
    from repro.parallel.steps import build_decode_step, build_prefill
    cfg = ModelConfig(name="bench-tiny", family="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                      d_ff=128, vocab_size=96, dtype=jnp.float32)
    mesh = make_mesh((2, 4), ("pod", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    toks = {}
    for strat in ("flat", "hier_rd"):
        ctx = ParallelCtx(tp_fast=("model",), tp_slow=("pod",),
                          ep=("model",), ar_strategy=strat)
        ap = make_plan(cfg, 8)
        params = init_params(jax.random.PRNGKey(0), ap)
        pre = build_prefill(ap, ctx, mesh, s_max=24)
        dec = build_decode_step(ap, ctx, mesh)
        prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, 96)
        nxt, cache = jax.jit(pre.fn)(params, prompts)
        seq = [np.asarray(nxt)]
        pos = jnp.full((4,), 8, jnp.int32)
        for i in range(6):
            nxt, cache = dec.jit()(params, cache, nxt, pos + i)
            seq.append(np.asarray(nxt))
        toks[strat] = np.stack(seq)
    same = bool(np.array_equal(toks["flat"], toks["hier_rd"]))
    emit("fig7/real_integration_tokens_match", float(same),
         "flat_vs_hier_rd_identical_generations")
    assert same


def crossover_sweep(out_path: str = "BENCH_crossover.json"):
    """Decode-regime crossover table: for each (model d_model x batch)
    decode all-reduce message size, the modelled per-strategy latency on the
    tpu_v5e NetworkSpec and the ``ar_strategy="auto"`` dispatcher's pick.

    This is the table the paper's Sec. 4.3/5 crossover claim reduces to for
    our target topology (16-wide ICI fast axis x 2/4 DCN pods); device-free.
    """
    import json
    from repro.core import autotune
    from repro.core.comm_model import TPU_V5E, decode_allreduce_bytes

    rows = []
    for d_model in (2048, 4096, 8192, 16384):
        for batch in (1, 8, 32, 128):
            msg = decode_allreduce_bytes(batch, d_model)  # bf16
            for slow in (2, 4):
                fast = 16
                times = autotune.predict_times(msg, fast, slow, TPU_V5E)
                pick = autotune.analytic_choice(msg, fast, slow, TPU_V5E)
                rows.append({
                    "d_model": d_model, "batch": batch, "msg_bytes": msg,
                    "fast": fast, "slow": slow,
                    "pick": pick.strategy, "rd_chunks": pick.rd_chunks,
                    "t_us": {s: t * 1e6 for s, t in times.items()},
                })
                emit(f"crossover/H{d_model}_B{batch}_pods{slow}",
                     times[pick.strategy] * 1e6,
                     f"msg_kb={msg // 1024};pick={pick.strategy}")
    # prefill-regime companion table: for prompt-sized residual messages,
    # the modelled fused-AR vs RS+AG (sequence-parallel) times and the
    # seq_parallel="auto" pick — decode rows above stay fused, these flip
    # to SP once bandwidth dominates (DESIGN.md §10)
    sp_rows = []
    for d_model in (2048, 4096, 8192):
        for prompt in (512, 2048, 8192):
            msg = prompt * d_model * 2  # bf16
            for slow in (2, 4):
                t = autotune.predict_sp_times(msg, 16, slow, TPU_V5E)
                sp = bool(t["rs_ag"] < t["fused"])
                sp_rows.append({
                    "d_model": d_model, "prompt_tokens": prompt,
                    "msg_bytes": msg, "fast": 16, "slow": slow,
                    "fused_us": t["fused"] * 1e6,
                    "rs_ag_us": t["rs_ag"] * 1e6, "sp": sp,
                })
                emit(f"crossover/sp_H{d_model}_S{prompt}_pods{slow}",
                     t["rs_ag"] * 1e6,
                     f"fused_us={t['fused']*1e6:.1f};sp={sp}")
    with open(out_path, "w") as f:
        json.dump({"network": "tpu_v5e", "rows": rows,
                   "sp_rows": sp_rows}, f, indent=2, sort_keys=True)
    emit("crossover/json_written", float(len(rows)), out_path)
    return rows


def run():
    simulated()
    real_integration()


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sweep", action="store_true",
                    help="emit the decode crossover table "
                         "(BENCH_crossover.json); device-free")
    ap.add_argument("--out", default="BENCH_crossover.json")
    args = ap.parse_args(argv)
    if args.sweep:
        crossover_sweep(args.out)
    else:
        run()


if __name__ == "__main__":
    main()
