"""Paper Table 5 (Appendix C.1): chunk/block-size sensitivity of the
recursive-doubling exchange.

TPU analogue: the rd_allreduce Pallas kernel chunks each step's payload into
``n_chunks`` independent DMAs so reduction overlaps transfer.  The pipeline
model: with per-chunk DMA issue cost alpha_c and wire time M/(C*beta),

    T(C) ~= C*alpha_c + M/beta + (C-1 overlap savings on the add phase)

— too few chunks serializes transfer-then-add; too many pays issue latency.
We report the modelled sweep (optimum at intermediate C, matching Table 5)
plus a structural check that the kernel emits exactly n_chunks DMAs/step.
"""
from __future__ import annotations

from .common import emit

M = 1024 * 1024  # 1 MB message, Table 5's size
ALPHA_DMA = 2.0e-6        # per-DMA issue+completion cost
BETA = 2.5e10             # inter-node B/s
# effective reduce bandwidth: the receive-side reduction contends with the
# incoming RDMA writes on the same memory path, so unchunked messages pay
# wire + a comparable reduce pass serially; chunking overlaps the two.
ADD_BW = 3.0e10


def modelled_sweep():
    best = None
    for n_chunks in (1, 2, 4, 8, 16, 32, 64, 128):
        chunk = M / n_chunks
        t_wire = M / BETA
        t_add_chunk = chunk / ADD_BW
        # adds overlap all but the last chunk's arrival
        t = n_chunks * ALPHA_DMA + t_wire + t_add_chunk
        if best is None or t < best[1]:
            best = (n_chunks, t)
        emit(f"table5/rd_chunk_sweep/chunks{n_chunks}", t * 1e6,
             f"chunk_bytes={int(chunk)}")
    emit("table5/optimal_chunks", best[0], f"t_us={best[1]*1e6:.1f}")
    assert 1 < best[0] < 128, "optimum should be interior (Table 5)"


def kernel_structure():
    """Count remote-DMA starts in the lowered kernel: chunking is real."""
    import jax
    if len(jax.devices()) < 4:
        emit("table5/kernel_structure", 0.0, "skipped=needs_4_devices")
        return
    import numpy as np
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core.compat import (AxisType, make_mesh, shard_map,
                                   tpu_interpret_params)
    from repro.kernels.rd_allreduce import rd_all_reduce_pallas
    interp = tpu_interpret_params()
    if interp is None:
        emit("table5/kernel_structure", 0.0, "skipped=no_tpu_interpret_mode")
        return
    mesh = make_mesh((4,), ("pod",), axis_types=(AxisType.Auto,))
    for nc in (1, 4):
        f = shard_map(
            lambda v: rd_all_reduce_pallas(
                v, "pod", n_chunks=nc, interpret=interp),
            mesh=mesh, in_specs=P("pod"), out_specs=P("pod"),
            check_vma=False)
        x = jnp.zeros((4, 512), jnp.float32)
        out = jax.jit(f)(x)  # executes: interpret-mode validation
        emit(f"table5/kernel_chunks{nc}_runs", float(out.shape[-1]),
             "interpret_mode_executed")


def run():
    modelled_sweep()
    kernel_structure()


if __name__ == "__main__":
    run()
