"""Fault-injected serving benchmark: goodput degradation under a
deterministic fault plan (DESIGN.md §11, docs/robustness.md).

The disaggregated pool pair replays the two phase-split trace shapes
under a seeded :class:`~repro.inference.faults.FaultPlan` swept over a
fault-rate ladder.  Because fault events are hash-thresholded (an event
fires iff ``hash_unit(...) < rate``), a higher rate injects a strict
superset of a lower rate's events — so the useful-work goodput fraction
``total_new / (total_new + wasted)`` (wasted = tokens decoded, then
discarded by a quarantine/OOM eviction and re-decoded) must degrade
monotonically in the rate, and every non-shed request must still emit
tokens bitwise-identical to the fault-free colocated reference
(recompute-from-scratch replays the stateless sampling chain).  Both
properties are asserted per cell, not just reported; tokens-per-step
throughput is also recorded but not monotonicity-gated — batching slack
absorbs recompute work unevenly, so only the work fraction is exact.

    python -m benchmarks.bench_faults --sweep    # writes BENCH_faults.json
    python -m benchmarks.bench_faults            # one smoke cell
"""
from __future__ import annotations

import json

import numpy as np

from .common import emit

S_MAX = 128
SLOTS = 4
N_REQ = 12
RATES = (0.0, 0.05, 0.1, 0.2)
TRACES = {
    # name -> (mean_in, mean_out): the two ends of the phase split
    "decode_heavy": (8, 24),
    "prefill_heavy": (40, 4),
}



def _setup():
    import jax
    from repro.configs import get_smoke
    from repro.models.transformer import make_plan, init_params
    cfg = get_smoke("llama3.2-1b")
    ap = make_plan(cfg, 1)
    params = init_params(jax.random.PRNGKey(0), ap)
    return cfg, ap, params


def _trace(cfg, mean_in, mean_out, seed=1):
    from repro.inference.scheduler import make_trace
    reqs = make_trace(N_REQ, mean_in=mean_in, mean_out=mean_out, rate=2.0,
                      vocab=cfg.vocab_size, seed=seed)
    for r in reqs:
        assert r.prompt.shape[0] + 1 <= S_MAX, r.prompt.shape
    return reqs


def _plan(rate: float):
    from repro.inference.faults import FaultPlan
    return FaultPlan(seed=7, handoff_drop=rate, handoff_corrupt=rate / 2,
                     prefill_stall=rate / 2, nan_logits=rate / 5)


def _reference(cfg, ap, params, mean_in, mean_out):
    """Fault-free colocated replay: the bitwise-parity oracle."""
    from repro.inference.spec import ReplicaSpec, build_replica
    sched = build_replica(ReplicaSpec(arch="llama3.2-1b", slots=SLOTS,
                                      s_max=S_MAX, block_size=8),
                          ap=ap, params=params)
    done = sched.run(_trace(cfg, mean_in, mean_out))
    assert all(r.output is not None for r in done)
    return {r.rid: r.output for r in done}


def _fault_cell(cfg, ap, params, name, mean_in, mean_out, rate, ref):
    from repro.inference.faults import FaultInjector
    from repro.inference.spec import ReplicaSpec, build_replica
    inj = FaultInjector(_plan(rate)) if rate > 0 else None
    coord = build_replica(
        ReplicaSpec(arch="llama3.2-1b", slots=SLOTS, s_max=S_MAX,
                    disagg=True, block_size=8, prefill_block_size=0),
        ap=ap, params=params, injector=inj)
    done = coord.run(_trace(cfg, mean_in, mean_out))
    shed = [r for r in done if r.output is None]
    # shed requests are *reported*, never silently dropped
    for r in shed:
        assert r.shed_reason, f"rid {r.rid} lost without a shed_reason"
    for r in done:      # every survivor matches the fault-free oracle
        if r.output is not None:
            assert np.array_equal(ref[r.rid], r.output), \
                f"rid {r.rid}: tokens diverge from fault-free reference"
    m = coord.metrics(done)
    assert m.completed + m.shed_requests == N_REQ, \
        (m.completed, m.shed_requests)
    wasted = m.decode_pool["wasted_tokens"]
    frac = m.total_new_tokens / max(m.total_new_tokens + wasted, 1)
    row = {"trace": name, "rate": rate, "mean_in": mean_in,
           "mean_out": mean_out, "goodput_frac": frac,
           "goodput_tok_per_step": m.total_new_tokens / max(m.steps, 1),
           "wasted_tokens": wasted,
           "quarantines": m.decode_pool["quarantines"], **m.to_dict()}
    return row, m


def sweep(out_path: str = "BENCH_faults.json"):
    cfg, ap, params = _setup()
    rows = []
    for name, (mi, mo) in TRACES.items():
        ref = _reference(cfg, ap, params, mi, mo)
        goodputs = []
        for rate in RATES:
            row, m = _fault_cell(cfg, ap, params, name, mi, mo, rate, ref)
            rows.append(row)
            goodputs.append(row["goodput_frac"])
            emit(f"faults/{name}_r{rate}", row["goodput_frac"],
                 f"tok_per_step={row['goodput_tok_per_step']:.2f};"
                 f"steps={m.steps};retries={m.handoff_retries};"
                 f"reprefills={m.handoff_reprefills};"
                 f"quarantines={row['quarantines']};shed={m.shed_requests}")
        for lo, hi in zip(goodputs[1:], goodputs[:-1]):
            assert lo <= hi + 1e-9, \
                f"{name}: goodput not monotone in fault rate {goodputs}"
        assert goodputs[RATES.index(0.1)] > 0.0, \
            f"{name}: zero goodput at 10% handoff-fault rate"
    summary = {
        "parity": "bitwise vs fault-free colocated (asserted per cell)",
        "monotone_goodput": True,
        "max_rate": max(RATES),
    }
    with open(out_path, "w") as f:
        json.dump({"arch": "llama3.2-1b(smoke)", "s_max": S_MAX,
                   "slots": SLOTS, "n_requests": N_REQ, "rates": RATES,
                   "summary": summary, "rows": rows},
                  f, indent=2, sort_keys=True, default=float)
    emit("faults/json_written", float(len(rows)), out_path)
    return rows


def run():
    cfg, ap, params = _setup()
    name, (mi, mo) = "decode_heavy", TRACES["decode_heavy"]
    ref = _reference(cfg, ap, params, mi, mo)
    row, m = _fault_cell(cfg, ap, params, name, mi, mo, 0.1, ref)
    emit("faults/smoke", row["goodput_frac"],
         f"tok_per_step={row['goodput_tok_per_step']:.2f};"
         f"retries={m.handoff_retries};shed={m.shed_requests}")


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sweep", action="store_true",
                    help="fault-rate ladder x both trace shapes "
                         "(BENCH_faults.json)")
    ap.add_argument("--out", default="BENCH_faults.json")
    args = ap.parse_args(argv)
    if args.sweep:
        sweep(args.out)
    else:
        run()


if __name__ == "__main__":
    main()
