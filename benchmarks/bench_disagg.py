"""Disaggregated vs colocated serving benchmark: TTFT/TPOT attribution
and per-pool all-reduce operating points.

Two trace shapes bracket the paper's phase split (Sec. 3.5): a
*decode-heavy* trace (short prompts, long generations — the latency-bound
small-message AR regime) and a *prefill-heavy* trace (long prompts, short
generations — bandwidth-bound large messages).  For each, the same trace
replays through the colocated paged batcher and through the
prefill/decode pool pair; tokens must match bitwise, and the disagg rows
additionally report the TTFT split (prefill + transfer), handoff volume,
and each pool's AR message-size bucket — the evidence that the two pools
key their dispatch tables on different regimes of the strategy crossover
(prefill bucket > decode bucket).

    python -m benchmarks.bench_disagg --sweep   # writes BENCH_disagg.json
    python -m benchmarks.bench_disagg           # quick smoke rows
"""
from __future__ import annotations

import json

import numpy as np

from .common import emit

S_MAX = 128
SLOTS = 4
N_REQ = 12
TRACES = {
    # name -> (mean_in, mean_out): the two ends of the phase split
    "decode_heavy": (8, 24),
    "prefill_heavy": (40, 4),
}


def _setup():
    import jax
    from repro.configs import get_smoke
    from repro.models.transformer import make_plan, init_params
    cfg = get_smoke("llama3.2-1b")
    ap = make_plan(cfg, 1)
    params = init_params(jax.random.PRNGKey(0), ap)
    return cfg, ap, params


def _trace(cfg, mean_in, mean_out, seed=1):
    from repro.inference.scheduler import make_trace
    reqs = make_trace(N_REQ, mean_in=mean_in, mean_out=mean_out, rate=2.0,
                      vocab=cfg.vocab_size, seed=seed)
    for r in reqs:   # the smoke geometry must hold every sampled prompt
        assert r.prompt.shape[0] + 1 <= S_MAX, r.prompt.shape
    return reqs


def _colocated_cell(cfg, ap, params, name, mean_in, mean_out):
    from repro.inference.spec import ReplicaSpec, build_replica
    sched = build_replica(ReplicaSpec(arch="llama3.2-1b", slots=SLOTS,
                                      s_max=S_MAX, block_size=8),
                          ap=ap, params=params)
    done = sched.run(_trace(cfg, mean_in, mean_out))
    assert all(r.output is not None for r in done)
    m = sched.metrics(done)
    outputs = {r.rid: r.output for r in done}
    row = {"trace": name, "mode": "colocated", "mean_in": mean_in,
           "mean_out": mean_out, **m.to_dict()}
    return row, outputs, m


def _disagg_cell(cfg, ap, params, name, mean_in, mean_out, ref_outputs):
    from repro.inference.spec import ReplicaSpec, build_replica
    coord = build_replica(
        ReplicaSpec(arch="llama3.2-1b", slots=SLOTS, s_max=S_MAX,
                    disagg=True, block_size=8, prefill_block_size=0),
        ap=ap, params=params)
    done = coord.run(_trace(cfg, mean_in, mean_out))
    assert all(r.output is not None for r in done)
    for r in done:   # the headline correctness bar: bitwise trace parity
        assert np.array_equal(ref_outputs[r.rid], r.output), \
            f"rid {r.rid}: disagg tokens diverge from colocated"
    m = coord.metrics(done)
    assert m.prefill_ar_bucket > m.decode_ar_bucket, \
        (m.prefill_ar_bucket, m.decode_ar_bucket)
    row = {"trace": name, "mode": "disagg", "mean_in": mean_in,
           "mean_out": mean_out, **m.to_dict()}
    return row, m


def sweep(out_path: str = "BENCH_disagg.json"):
    cfg, ap, params = _setup()
    rows = []
    for name, (mi, mo) in TRACES.items():
        crow, ref, cm = _colocated_cell(cfg, ap, params, name, mi, mo)
        rows.append(crow)
        emit(f"disagg/{name}_colocated", cm.ttft_steps_p50,
             f"tpot_p50={cm.tpot_steps_p50:.2f};steps={cm.steps}")
        drow, dm = _disagg_cell(cfg, ap, params, name, mi, mo, ref)
        rows.append(drow)
        emit(f"disagg/{name}_disagg", dm.ttft_steps_p50,
             f"prefill_p50={dm.prefill_steps_p50:.1f};"
             f"transfer_p50={dm.transfer_steps_p50:.1f};"
             f"tpot_p50={dm.tpot_steps_p50:.2f};"
             f"ar_buckets={dm.prefill_ar_bucket}>{dm.decode_ar_bucket};"
             f"xfer_kib={dm.transfer_bytes / 1024:.0f}")
    summary = {
        "parity": "bitwise (asserted per cell)",
        "prefill_ar_bucket": max(r["prefill_ar_bucket"] for r in rows
                                 if r["mode"] == "disagg"),
        "decode_ar_bucket": max(r["decode_ar_bucket"] for r in rows
                                if r["mode"] == "disagg"),
    }
    with open(out_path, "w") as f:
        json.dump({"arch": "llama3.2-1b(smoke)", "s_max": S_MAX,
                   "slots": SLOTS, "n_requests": N_REQ,
                   "summary": summary, "rows": rows},
                  f, indent=2, sort_keys=True, default=float)
    emit("disagg/json_written", float(len(rows)), out_path)
    return rows


def run():
    cfg, ap, params = _setup()
    name, (mi, mo) = "decode_heavy", TRACES["decode_heavy"]
    crow, ref, cm = _colocated_cell(cfg, ap, params, name, mi, mo)
    drow, dm = _disagg_cell(cfg, ap, params, name, mi, mo, ref)
    emit("disagg/smoke", dm.ttft_steps_p50,
         f"colocated_ttft={cm.ttft_steps_p50:.1f};"
         f"ar_buckets={dm.prefill_ar_bucket}>{dm.decode_ar_bucket}")


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sweep", action="store_true",
                    help="both trace shapes x {colocated, disagg} "
                         "(BENCH_disagg.json)")
    ap.add_argument("--out", default="BENCH_disagg.json")
    args = ap.parse_args(argv)
    if args.sweep:
        sweep(args.out)
    else:
        run()


if __name__ == "__main__":
    main()
