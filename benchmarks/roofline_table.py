"""Summarize the roofline sweep JSONs (written by repro.launch.roofline)
into harness CSV rows + the EXPERIMENTS.md table body."""
from __future__ import annotations

import glob
import json
import os

from .common import emit


def load(roofline_dir: str = "experiments/roofline"):
    recs = []
    for p in sorted(glob.glob(os.path.join(roofline_dir, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return [r for r in recs if r.get("status") == "ok"]


def run():
    recs = load()
    if not recs:
        emit("roofline/none", 0.0,
             "run 'python -m repro.launch.roofline --all' first")
        return
    for r in recs:
        name = (f"roofline/{r['mesh']}/{r['arch']}/{r['shape']}"
                + ("/xpod" if r.get("cross_pod_tp") else "")
                + (f"/{r['strategy']}" if r.get("strategy", "flat") != "flat"
                   else ""))
        emit(name, r["bound_step_s"] * 1e6,
             f"dom={r['dominant']};frac={r['dominant_frac']:.2f};"
             f"compute_ms={r['compute_s']*1e3:.2f};"
             f"memory_ms={r['memory_s']*1e3:.2f};"
             f"coll_ms={r['collective_s']*1e3:.2f};"
             f"useful={r['useful_flops_ratio']:.2f}")


def markdown_table(roofline_dir: str = "experiments/roofline",
                   include_variants: bool = False) -> str:
    recs = load(roofline_dir)
    if not include_variants:
        recs = [r for r in recs if not r.get("variant")]
    lines = [
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
        "| dominant | MODEL/HLO flops | step bound (s) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    seen = set()
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"], x["mesh"],
                                         x.get("strategy", ""),
                                         x.get("variant", ""))):
        tag = r["mesh"] + (" xpod" if r.get("cross_pod_tp") else "") + \
            (f" {r['strategy']}" if r.get("strategy", "flat") != "flat"
             else "") + \
            (f" [{r['variant']}]" if r.get("variant") else "")
        key = (r["arch"], r["shape"], tag)
        if key in seen:
            continue
        seen.add(key)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {tag} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {r['bound_step_s']:.3e} |")
    return "\n".join(lines)


if __name__ == "__main__":
    run()
    print(markdown_table())
