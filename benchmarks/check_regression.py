"""Bench-regression gate: compare fresh bench JSONs against committed
baselines and fail on drift.

    python -m benchmarks.check_regression \\
        --baseline-allreduce base/BENCH_allreduce.json \\
        --fresh-allreduce BENCH_allreduce.json \\
        --baseline-serve base/BENCH_serve.json \\
        --fresh-serve BENCH_serve.json \\
        [--baseline-spec base/BENCH_spec.json --fresh-spec BENCH_spec.json] \\
        [--baseline-disagg base/BENCH_disagg.json \\
         --fresh-disagg BENCH_disagg.json] \\
        [--baseline-faults base/BENCH_faults.json \\
         --fresh-faults BENCH_faults.json] \\
        [--baseline-router base/BENCH_router.json \\
         --fresh-router BENCH_router.json] \\
        [--baseline-prefix base/BENCH_prefix.json \\
         --fresh-prefix BENCH_prefix.json] \\
        [--threshold 0.25]

What is compared (chosen to be meaningful on shared CI runners):

* ``BENCH_allreduce.json`` — the dispatcher's chosen-vs-best **regret**,
  aggregated as the mean over size buckets.  Individual CPU collective
  timings are jittery, so only the aggregate is gated, with an absolute
  slack floor on top of the relative threshold.  The RS+AG ``sp_rows``
  are additionally gated on their HLO-structural / analytic fields
  (per-collective wire-byte ratio, collective count, SP-vs-fused
  dispatch) which are deterministic on any runner.  The quantized-wire
  ``quant_rows`` are gated the same way (wire bytes, collective count,
  wire-reduction factor, analytic ``ar_quant="auto"`` level per bucket)
  while their CPU latency columns stay ungated.
* ``BENCH_serve.json`` — the trace-replay **logical-step** metrics
  (TTFT/TPOT p50/p99 in steps, step counts, emitted tokens, peak KV
  footprint).  These are deterministic given the seeded trace, so any
  drift beyond the threshold is a real behavior change, not noise.
* ``BENCH_spec.json`` (optional) — per-(k, drafter) acceptance rate and
  step counts, deterministic for the same reason.
* ``BENCH_disagg.json`` (optional) — colocated-vs-disaggregated
  logical-step metrics per trace shape, plus the per-pool AR buckets
  (the prefill > decode bucket ordering is asserted inside the bench
  itself; here we gate drift of the deterministic fields).
* ``BENCH_faults.json`` (optional) — fault-injected goodput per
  (trace, fault rate) cell.  Bitwise parity and goodput monotonicity
  are asserted inside the bench; the deterministic per-cell counters
  (goodput fraction, retries, re-prefills, quarantines, sheds) are
  gated here so a recovery-path change cannot silently alter the
  fault response.
* ``BENCH_prefix.json`` (optional) — prefix-cache hit rate, spliced
  prompt tokens, and prefill-step reduction per (shared_frac, slots)
  cell.  Bitwise on==off parity and hit-rate monotonicity in the
  sharing fraction are asserted inside the bench; the deterministic
  per-cell counters are gated here.
* ``BENCH_router.json`` (optional) — placement-policy A/B per
  (trace, policy) cell on the 2-replica fleet.  Placement runs on the
  shared logical clock, so per-replica placements, load imbalance, and
  the merged step-domain fleet metrics are deterministic; the bursty
  ``ttft_aware`` <= ``round_robin`` tail-TTFT ordering is asserted
  inside the bench itself.

Exit code 1 with a per-field report when any check trips.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

# Deterministic (logical-step / token-count) ServeMetrics fields.
SERVE_FIELDS = ("ttft_steps_p50", "ttft_steps_p99", "tpot_steps_p50",
                "tpot_steps_p99", "steps", "total_new_tokens",
                "peak_kv_tokens", "preemptions", "completed")
SPEC_FIELDS = ("acceptance_rate", "accepted_tokens", "spec_steps", "steps",
               "total_new_tokens", "step_ratio")
# Disagg rows are a union of ServeMetrics (colocated) and DisaggMetrics
# (disagg) fields; _check_rows skips fields absent from a row's baseline.
DISAGG_FIELDS = ("steps", "total_new_tokens", "completed", "preemptions",
                 "ttft_steps_p50", "tpot_steps_p50", "handoffs",
                 "transfer_bytes", "prefill_ar_bucket", "decode_ar_bucket")
# RS+AG (sequence-parallel) rows of BENCH_allreduce.json: HLO-structural
# and analytic fields only — deterministic on any runner.  Latency columns
# (rs_ag_us / fused_flat_us) are deliberately ungated (CPU jitter).
SP_FIELDS = ("per_coll_ratio", "auto_sp", "fused_per_coll_wire_bytes",
             "rs_ag_per_coll_wire_bytes", "rs_ag_collectives")
# Quantized-wire rows of BENCH_allreduce.json: HLO wire accounting and
# the analytic ar_quant="auto" level per bucket are deterministic on any
# runner; the latency columns (q_us / fp_us) are deliberately ungated.
QUANT_FIELDS = ("wire_reduction", "q_wire_bytes", "fp_wire_bytes",
                "q_collectives", "auto_bits")
# Fault-injected cells: the schedule is a pure hash of (seed, kind, ids),
# so every counter below is deterministic on any runner.
FAULT_FIELDS = ("goodput_frac", "goodput_tok_per_step", "ttft_steps_p99",
                "steps", "total_new_tokens", "completed", "shed_requests",
                "wasted_tokens", "handoff_retries", "handoff_reprefills",
                "quarantines")
# Prefix-cache cells: the trace, the trie walk, and the chunk-aligned
# splice cap are all seeded/deterministic, so hit counts and
# tokens-saved are exact; a splice-policy change that loses hits (or a
# trie leak that gains phantom ones) must show here.  Bitwise parity and
# frac-monotonicity are asserted inside the bench itself.
PREFIX_FIELDS = ("prefix_hits", "prefix_tokens_saved", "prefix_hit_rate",
                 "prefill_chunks_skipped", "ar_bytes_saved", "steps",
                 "step_ratio", "total_new_tokens", "completed",
                 "peak_kv_tokens")
# Router A/B cells: placement is a pure function of the shared logical
# clock, so per-replica placements and the merged step-domain fleet
# metrics are deterministic.  A policy change that shifts traffic or
# degrades tail TTFT must show here (the bursty ttft_aware <= round_robin
# ordering itself is asserted inside the bench).
ROUTER_FIELDS = ("ttft_steps_p50", "ttft_steps_p99", "tpot_steps_p50",
                 "steps", "total_new_tokens", "completed",
                 "goodput_tok_per_step", "placements_0", "placements_1",
                 "load_imbalance")
# Regret on CPU runners is noisy; gate the mean with extra absolute slack.
REGRET_ABS_SLACK = 0.5


def _load(path: str) -> Dict:
    with open(path) as f:
        return json.load(f)


def _drift(base: float, fresh: float) -> float:
    """Symmetric-denominator relative drift with a unit floor so
    near-zero baselines don't explode."""
    return abs(fresh - base) / max(abs(base), 1.0)


def _serve_key(row: Dict) -> tuple:
    return (row.get("rate"), row.get("slots"), row.get("block_size"),
            row.get("n_blocks"), bool(row.get("tight_pool")),
            bool(row.get("decode_heavy")))


def _spec_key(row: Dict) -> tuple:
    return (row.get("k"), row.get("drafter"))


def _disagg_key(row: Dict) -> tuple:
    return (row.get("trace"), row.get("mode"))


def _fault_key(row: Dict) -> tuple:
    return (row.get("trace"), row.get("rate"))


def _router_key(row: Dict) -> tuple:
    return (row.get("trace"), row.get("policy"))


def _prefix_key(row: Dict) -> tuple:
    return (row.get("shared_frac"), row.get("slots"))


def _check_rows(base_rows: List[Dict], fresh_rows: List[Dict], key_fn,
                fields, threshold: float, label: str,
                failures: List[str]) -> None:
    base_by = {key_fn(r): r for r in base_rows}
    fresh_by = {key_fn(r): r for r in fresh_rows}
    missing = set(base_by) - set(fresh_by)
    if missing:
        failures.append(f"{label}: fresh run lost cells {sorted(missing)}")
    for key in sorted(set(base_by) & set(fresh_by), key=str):
        b, f = base_by[key], fresh_by[key]
        for field in fields:
            if field not in b:       # baseline predates the field
                continue
            if field not in f:
                failures.append(f"{label}{key}: field {field!r} missing "
                                f"from fresh row")
                continue
            d = _drift(float(b[field]), float(f[field]))
            if d > threshold:
                failures.append(
                    f"{label}{key}.{field}: baseline {b[field]:.4g} -> "
                    f"fresh {f[field]:.4g} (drift {d:.1%} > "
                    f"{threshold:.0%})")


def check_allreduce(base: Dict, fresh: Dict, threshold: float,
                    failures: List[str]) -> None:
    for doc, name in ((base, "baseline"), (fresh, "fresh")):
        if not doc.get("picks"):
            failures.append(f"allreduce: {name} JSON has no 'picks'")
            return
        if "tuned_table" not in doc:
            failures.append(f"allreduce: {name} JSON has no 'tuned_table'")
            return
    def mean_regret(doc):
        rs = [max(0.0, float(p["regret"])) for p in doc["picks"]]
        return sum(rs) / len(rs)
    b, f = mean_regret(base), mean_regret(fresh)
    if f > b * (1.0 + threshold) + REGRET_ABS_SLACK:
        failures.append(
            f"allreduce mean regret: baseline {b:.3f} -> fresh {f:.3f} "
            f"(allowed <= {b * (1 + threshold) + REGRET_ABS_SLACK:.3f})")
    # RS+AG (sequence-parallel) structural rows: deterministic per size
    if base.get("sp_rows"):
        if not fresh.get("sp_rows"):
            failures.append("allreduce: fresh JSON lost 'sp_rows'")
        else:
            _check_rows(base["sp_rows"], fresh["sp_rows"],
                        lambda r: r.get("msg_bytes"), SP_FIELDS,
                        threshold, "allreduce.sp", failures)
    # Quantized-wire structural rows: a compression or dispatch change
    # that shrinks the wire win (or flips an auto bucket) must show here.
    if base.get("quant_rows"):
        if not fresh.get("quant_rows"):
            failures.append("allreduce: fresh JSON lost 'quant_rows'")
        else:
            _check_rows(base["quant_rows"], fresh["quant_rows"],
                        lambda r: (r.get("msg_bytes"), r.get("quant")),
                        QUANT_FIELDS, threshold, "allreduce.quant",
                        failures)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--baseline-allreduce", required=True)
    p.add_argument("--fresh-allreduce", required=True)
    p.add_argument("--baseline-serve", required=True)
    p.add_argument("--fresh-serve", required=True)
    p.add_argument("--baseline-spec", default=None)
    p.add_argument("--fresh-spec", default=None)
    p.add_argument("--baseline-disagg", default=None)
    p.add_argument("--fresh-disagg", default=None)
    p.add_argument("--baseline-faults", default=None)
    p.add_argument("--fresh-faults", default=None)
    p.add_argument("--baseline-router", default=None)
    p.add_argument("--fresh-router", default=None)
    p.add_argument("--baseline-prefix", default=None)
    p.add_argument("--fresh-prefix", default=None)
    p.add_argument("--threshold", type=float, default=0.25,
                   help="max allowed relative drift (default 0.25)")
    args = p.parse_args(argv)

    failures: List[str] = []
    check_allreduce(_load(args.baseline_allreduce),
                    _load(args.fresh_allreduce), args.threshold, failures)
    _check_rows(_load(args.baseline_serve)["rows"],
                _load(args.fresh_serve)["rows"], _serve_key, SERVE_FIELDS,
                args.threshold, "serve", failures)
    if args.baseline_spec and args.fresh_spec:
        _check_rows(_load(args.baseline_spec)["rows"],
                    _load(args.fresh_spec)["rows"], _spec_key, SPEC_FIELDS,
                    args.threshold, "spec", failures)
    if args.baseline_disagg and args.fresh_disagg:
        _check_rows(_load(args.baseline_disagg)["rows"],
                    _load(args.fresh_disagg)["rows"], _disagg_key,
                    DISAGG_FIELDS, args.threshold, "disagg", failures)
    if args.baseline_faults and args.fresh_faults:
        _check_rows(_load(args.baseline_faults)["rows"],
                    _load(args.fresh_faults)["rows"], _fault_key,
                    FAULT_FIELDS, args.threshold, "faults", failures)
    if args.baseline_router and args.fresh_router:
        _check_rows(_load(args.baseline_router)["rows"],
                    _load(args.fresh_router)["rows"], _router_key,
                    ROUTER_FIELDS, args.threshold, "router", failures)
    if args.baseline_prefix and args.fresh_prefix:
        _check_rows(_load(args.baseline_prefix)["rows"],
                    _load(args.fresh_prefix)["rows"], _prefix_key,
                    PREFIX_FIELDS, args.threshold, "prefix", failures)

    if failures:
        print(f"[check_regression] FAIL ({len(failures)} violations):")
        for msg in failures:
            print(f"  - {msg}")
        return 1
    print("[check_regression] OK: benches within "
          f"{args.threshold:.0%} of baselines")
    return 0


if __name__ == "__main__":
    sys.exit(main())
