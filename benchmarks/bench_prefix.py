"""Prefix-cache benchmark: sharing fraction x slot count.

For each (shared_frac, slots) cell a shared-prefix trace replays through
the continuous batcher with the radix trie on, and we record the hit
rate, prompt tokens spliced instead of re-prefilled, the prefill-step
reduction against the prefix-off baseline, and the all-reduce traffic
those skipped chunks never generate (each chunk of C tokens pays
2 x n_layers tensor-parallel all-reduces over a (C, d_model) activation
— the paper's per-token AR tax; splicing deletes it outright, the only
mitigation better than a faster all-reduce).  Logical-step metrics are
deterministic given the seeded trace, so the numbers are CI-stable.

Every cell is asserted bitwise-equal to its prefix-off twin before the
row is recorded — the benchmark cannot silently trade correctness for
hit rate — and both hit rate and tokens saved must be monotone
non-decreasing in the sharing fraction at fixed slots.

    python -m benchmarks.bench_prefix --sweep   # writes BENCH_prefix.json
    python -m benchmarks.bench_prefix           # quick smoke cell
"""
from __future__ import annotations

import json

import numpy as np

from .common import emit

S_MAX = 96
N_REQ = 12
PREFIX_LEN = 32
ADMIT_CHUNK = 16
MEAN_IN, MEAN_OUT = 12, 8
FRACS = (0.0, 0.5, 1.0)
SLOT_COUNTS = (2, 4)


def _make_reqs(vocab, shared_frac, seed=3):
    from repro.inference.scheduler import make_prefix_trace
    return make_prefix_trace(N_REQ, prefix_len=PREFIX_LEN,
                             shared_frac=shared_frac, mean_in=MEAN_IN,
                             mean_out=MEAN_OUT, rate=3.0, vocab=vocab,
                             seed=seed, clip_len=S_MAX - 1)


def _run(ap, params, vocab, shared_frac, slots, *, prefix="on"):
    from repro.inference.spec import ReplicaSpec, build_replica
    sched = build_replica(
        ReplicaSpec(arch="llama3.2-1b", slots=slots, s_max=S_MAX,
                    block_size=8, admit_mode="chunked",
                    admit_chunk=ADMIT_CHUNK, prefix_cache=prefix),
        ap=ap, params=params)
    done = sched.run(_make_reqs(vocab, shared_frac))
    assert all(r.output is not None for r in done), "dropped requests"
    sched.alloc.check()
    return {r.rid: r.output for r in done}, sched.metrics(done)


def sweep(out_path: str = "BENCH_prefix.json"):
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke
    from repro.core.autotune import _bucket
    from repro.models.transformer import make_plan, init_params

    cfg = get_smoke("llama3.2-1b")
    itemsize = jnp.dtype(cfg.dtype).itemsize
    ap = make_plan(cfg, 1)
    params = init_params(jax.random.PRNGKey(0), ap)
    # AR bytes one spliced chunk never pays: 2 collectives per layer over
    # the (ADMIT_CHUNK, d_model) activation
    chunk_ar_bytes = 2 * cfg.n_layers * ADMIT_CHUNK * cfg.d_model * itemsize

    rows = []
    for slots in SLOT_COUNTS:
        for frac in FRACS:
            off, m_off = _run(ap, params, cfg.vocab_size, frac, slots,
                              prefix="off")
            on, m = _run(ap, params, cfg.vocab_size, frac, slots)
            for rid in off:
                assert np.array_equal(off[rid], on[rid]), \
                    (frac, slots, rid)
            saved_chunks = m.prefix_tokens_saved // ADMIT_CHUNK
            rows.append({
                "shared_frac": frac, "slots": slots,
                "baseline_steps": m_off.steps,
                "step_ratio": m.steps / m_off.steps,
                "prefill_chunks_skipped": saved_chunks,
                "ar_bytes_saved": saved_chunks * chunk_ar_bytes,
                "ar_bucket_chunk": _bucket(chunk_ar_bytes),
                **m.to_dict(),
            })
            emit(f"prefix/frac{frac}_s{slots}", m.prefix_hit_rate,
                 f"saved={m.prefix_tokens_saved}tok;"
                 f"steps={m.steps}/{m_off.steps};"
                 f"ar_saved={saved_chunks * chunk_ar_bytes}B")
        # monotonicity in the sharing fraction at fixed slots: more
        # sharing can only add hits (make_prefix_trace draws each
        # request's share coin from the same per-request stream)
        cells = [r for r in rows if r["slots"] == slots]
        for lo, hi in zip(cells, cells[1:]):
            assert hi["prefix_hit_rate"] >= lo["prefix_hit_rate"], \
                (slots, lo["shared_frac"], hi["shared_frac"])
            assert hi["prefix_tokens_saved"] >= lo["prefix_tokens_saved"], \
                (slots, lo["shared_frac"], hi["shared_frac"])
        assert cells[0]["prefix_tokens_saved"] == 0, \
            "frac=0.0 must not share anything"
        assert cells[-1]["prefix_tokens_saved"] > 0, \
            "frac=1.0 must actually splice"

    summary = {
        "hit_rate_by_cell": {f"{r['shared_frac']}x{r['slots']}":
                             r["prefix_hit_rate"] for r in rows},
        "tokens_saved_by_cell": {f"{r['shared_frac']}x{r['slots']}":
                                 r["prefix_tokens_saved"] for r in rows},
        "max_ar_bytes_saved": max(r["ar_bytes_saved"] for r in rows),
        "best_step_ratio": min(r["step_ratio"] for r in rows),
    }
    with open(out_path, "w") as f:
        json.dump({"arch": "llama3.2-1b(smoke)", "s_max": S_MAX,
                   "n_requests": N_REQ, "prefix_len": PREFIX_LEN,
                   "admit_chunk": ADMIT_CHUNK,
                   "summary": summary, "rows": rows},
                  f, indent=2, sort_keys=True, default=float)
    emit("prefix/json_written", float(len(rows)), out_path)
    return rows


def run():
    import jax
    from repro.configs import get_smoke
    from repro.models.transformer import make_plan, init_params
    cfg = get_smoke("llama3.2-1b")
    ap = make_plan(cfg, 1)
    params = init_params(jax.random.PRNGKey(0), ap)
    off, _ = _run(ap, params, cfg.vocab_size, 0.7, 4, prefix="off")
    on, m = _run(ap, params, cfg.vocab_size, 0.7, 4)
    for rid in off:
        assert np.array_equal(off[rid], on[rid]), rid
    assert m.prefix_tokens_saved > 0
    emit("prefix/smoke_frac0.7_s4", m.prefix_hit_rate,
         f"saved={m.prefix_tokens_saved}tok")


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sweep", action="store_true",
                    help="full shared_frac x slots grid "
                         "(BENCH_prefix.json)")
    ap.add_argument("--out", default="BENCH_prefix.json")
    args = ap.parse_args(argv)
    if args.sweep:
        sweep(args.out)
    else:
        run()


if __name__ == "__main__":
    main()
