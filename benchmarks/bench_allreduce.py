"""Paper Figs. 4 & 6 (+ Appendix C.3): all-reduce algorithm comparison.

Three evidence channels (no real interconnect in this container):
1. alpha-beta model sweep — NCCL Ring/Tree vs NVRAR across message sizes and
   GPU counts on Perlmutter/Vista constants (the paper's own modelling
   frame, Eqs. 1-6);
2. HLO-structural measurement — lower the hierarchical vs flat strategies on
   the 512-chip multi-pod mesh with cross-pod TP and compare *slow-axis
   (DCN) collective payload bytes* from the lowered module: NVRAR's
   reduce-scatter shrinks the inter-node payload by G=16x;
3. the TPU-target projection with v5e ICI/DCN constants.
"""
from __future__ import annotations

from .common import emit


KB = 1024


def model_sweep():
    from repro.core import comm_model as cm
    for net in (cm.PERLMUTTER, cm.VISTA):
        for msg_kb in (64, 128, 256, 512, 1024, 2048, 4096):
            for ngpu in (8, 16, 32, 64, 128):
                n_nodes = max(1, ngpu // net.gpus_per_node)
                g = min(ngpu, net.gpus_per_node)
                if n_nodes < 2:
                    continue
                algo, t_nccl = cm.nccl_model_best(msg_kb * KB, n_nodes, g,
                                                  net)
                t_nv = cm.t_nvrar(msg_kb * KB, n_nodes, g, net)
                emit(f"fig6/{net.name}/allreduce_{msg_kb}KB_{ngpu}gpu",
                     t_nv * 1e6,
                     f"nccl_{algo}_us={t_nccl*1e6:.1f};"
                     f"speedup={t_nccl/t_nv:.2f}x")


def tpu_projection():
    from repro.core import comm_model as cm
    net = cm.TPU_V5E
    for msg_kb in (128, 256, 1024):
        for pods in (2, 4, 8):
            t_ring = cm.t_ring_allreduce(msg_kb * KB, pods, 16, net)
            t_nv = cm.t_nvrar(msg_kb * KB, pods, 16, net)
            emit(f"tpu/allreduce_{msg_kb}KB_{pods}pods", t_nv * 1e6,
                 f"flat_ring_us={t_ring*1e6:.1f};"
                 f"speedup={t_ring/t_nv:.2f}x")


def hlo_structural():
    """DCN payload per decode step: flat vs hierarchical strategies, lowered
    on the 2x16x16 mesh with TP spanning the pod (DCN) axis."""
    import os
    if len(__import__("jax").devices()) < 512:
        emit("fig6/hlo_structural", 0.0, "skipped=needs_512_devices")
        return
    from repro.launch.mesh import make_production_mesh
    from repro.launch.input_specs import build_cell
    from repro.launch.hlo_analysis import collective_bytes
    mesh = make_production_mesh(multi_pod=True)
    res = {}
    for strat in ("flat", "hier_rd", "hier_rd_halving"):
        cell = build_cell("llama3.2-1b", "decode_32k", mesh,
                          ar_strategy=strat, cross_pod_tp=True)
        lowered = cell.lower()
        st = collective_bytes(lowered.as_text(dialect="hlo"), 512, 2)
        res[strat] = st
        emit(f"fig6/hlo/decode_dcn_bytes_{strat}", st.dcn_bytes,
             f"ici_bytes={st.ici_bytes};n_colls={st.count}")
    if res["flat"].dcn_bytes > 0:
        emit("fig6/hlo/dcn_reduction_hier_vs_flat",
             res["flat"].dcn_bytes / max(res["hier_rd"].dcn_bytes, 1),
             "per_layer_inter_payload_shrinks_by_G")


def run():
    model_sweep()
    tpu_projection()
    hlo_structural()


if __name__ == "__main__":
    run()
