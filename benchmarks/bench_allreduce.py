"""Paper Figs. 4 & 6 (+ Appendix C.3): all-reduce algorithm comparison.

Four evidence channels (no real interconnect in this container):
1. alpha-beta model sweep — NCCL Ring/Tree vs NVRAR across message sizes and
   GPU counts on Perlmutter/Vista constants (the paper's own modelling
   frame, Eqs. 1-6);
2. HLO-structural measurement — lower the hierarchical vs flat strategies on
   the 512-chip multi-pod mesh with cross-pod TP and compare *slow-axis
   (DCN) collective payload bytes* from the lowered module: NVRAR's
   reduce-scatter shrinks the inter-node payload by G=16x;
3. the TPU-target projection with v5e ICI/DCN constants;
4. ``--sweep``: a REAL strategy x message-size latency grid measured on 8
   simulated host devices, cross-checked against the autotuned dispatcher's
   per-bucket pick (chosen-vs-best regret), persisted to
   ``BENCH_allreduce.json`` so the perf trajectory is tracked across PRs.
   The sweep also carries an **RS+AG column** (``sp_rows``): the
   sequence-parallel decomposition of the same residual message — measured
   pair latency, per-collective wire bytes from the lowered HLO (asserted
   <= half the fused single-collective all-reduce's), and the
   ``seq_parallel="auto"`` dispatcher's SP-vs-fused pick per size
   (DESIGN.md §10: prefill-sized messages decompose, decode-sized stay on
   the fused hierarchical-RD path).
   A **quantized-wire column** (``quant_rows``) measures the int8/int4
   compressed hierarchical all-reduce against the bf16 fp path at each
   size: per-module wire bytes from the lowered HLO (asserted >= 1.9x /
   3.5x smaller in the 128KB-2MB window — the packed payload plus bf16
   group scales), measured latency, and the deterministic
   ``ar_quant="auto"`` analytic level per bucket (quantized at >= 1
   bandwidth-bound size, fp at decode-sized messages; DESIGN.md §12).
"""
from __future__ import annotations

from .common import emit


KB = 1024
MB = 1024 * KB

# --sweep grid: decode-regime through clearly bandwidth-bound messages.
SWEEP_SIZES = (16 * KB, 64 * KB, 256 * KB, 1 * MB, 4 * MB, 16 * MB)
SWEEP_STRATEGIES = ("flat", "hier_ring", "hier_rd", "hier_rd_halving")


def model_sweep():
    from repro.core import comm_model as cm
    for net in (cm.PERLMUTTER, cm.VISTA):
        for msg_kb in (64, 128, 256, 512, 1024, 2048, 4096):
            for ngpu in (8, 16, 32, 64, 128):
                n_nodes = max(1, ngpu // net.gpus_per_node)
                g = min(ngpu, net.gpus_per_node)
                if n_nodes < 2:
                    continue
                algo, t_nccl = cm.nccl_model_best(msg_kb * KB, n_nodes, g,
                                                  net)
                t_nv = cm.t_nvrar(msg_kb * KB, n_nodes, g, net)
                emit(f"fig6/{net.name}/allreduce_{msg_kb}KB_{ngpu}gpu",
                     t_nv * 1e6,
                     f"nccl_{algo}_us={t_nccl*1e6:.1f};"
                     f"speedup={t_nccl/t_nv:.2f}x")


def tpu_projection():
    from repro.core import comm_model as cm
    net = cm.TPU_V5E
    for msg_kb in (128, 256, 1024):
        for pods in (2, 4, 8):
            t_ring = cm.t_ring_allreduce(msg_kb * KB, pods, 16, net)
            t_nv = cm.t_nvrar(msg_kb * KB, pods, 16, net)
            emit(f"tpu/allreduce_{msg_kb}KB_{pods}pods", t_nv * 1e6,
                 f"flat_ring_us={t_ring*1e6:.1f};"
                 f"speedup={t_ring/t_nv:.2f}x")


def hlo_structural():
    """DCN payload per decode step: flat vs hierarchical strategies, lowered
    on the 2x16x16 mesh with TP spanning the pod (DCN) axis."""
    import os
    if len(__import__("jax").devices()) < 512:
        emit("fig6/hlo_structural", 0.0, "skipped=needs_512_devices")
        return
    from repro.launch.mesh import make_production_mesh
    from repro.launch.input_specs import build_cell
    from repro.launch.hlo_analysis import collective_bytes
    mesh = make_production_mesh(multi_pod=True)
    res = {}
    for strat in ("flat", "hier_rd", "hier_rd_halving"):
        cell = build_cell("llama3.2-1b", "decode_32k", mesh,
                          ar_strategy=strat, cross_pod_tp=True)
        lowered = cell.lower()
        st = collective_bytes(lowered.as_text(dialect="hlo"), 512, 2)
        res[strat] = st
        emit(f"fig6/hlo/decode_dcn_bytes_{strat}", st.dcn_bytes,
             f"ici_bytes={st.ici_bytes};n_colls={st.count}")
    if res["flat"].dcn_bytes > 0:
        emit("fig6/hlo/dcn_reduction_hier_vs_flat",
             res["flat"].dcn_bytes / max(res["hier_rd"].dcn_bytes, 1),
             "per_layer_inter_payload_shrinks_by_G")


def measured_sweep(out_path: str = "BENCH_allreduce.json"):
    """Measure every strategy at every SWEEP_SIZES message on an 8-device
    (2 pod x 4 model) host mesh, record into an AutoTuner, and emit the
    strategy grid + the dispatcher's chosen-vs-best regret per size bucket.

    Requires >= 8 devices (the ``--sweep`` entry point forces them before
    jax initializes).
    """
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.compat import make_mesh, shard_map
    from repro.core import (tp_all_reduce, tp_reduce_scatter, tp_all_gather,
                            ParallelCtx, autotune)
    from repro.core import comm_model as cm
    from repro.launch.hlo_analysis import collective_bytes
    from .common import timeit

    if len(jax.devices()) < 8:
        emit("sweep/skipped", 0.0, "needs_8_devices")
        return None

    mesh = make_mesh((2, 4), ("pod", "model"))
    fast_n, slow_n = 4, 2

    def _shmap(fn):
        return jax.jit(shard_map(fn, mesh=mesh, in_specs=P(),
                                 out_specs=P(), check_vma=False))

    def _per_coll_wire(f, x):
        """Mean per-collective wire bytes of a lowered executable."""
        st = collective_bytes(f.lower(x).as_text(dialect="hlo"), 8, 2)
        assert st.count > 0, "no collectives in lowered module"
        return (st.wire_ici_bytes + st.wire_dcn_bytes) / st.count, st.count

    tuner = autotune.AutoTuner(cm.TPU_V5E)
    grid = []
    picks = []
    sp_rows = []
    quant_rows = []
    for msg_bytes in SWEEP_SIZES:
        n_elems = msg_bytes // 4  # f32 payload
        x = np.random.default_rng(0).standard_normal(n_elems) \
            .astype(np.float32)
        measured = {}
        for strat in SWEEP_STRATEGIES:
            ctx = ParallelCtx(tp_fast=("model",), tp_slow=("pod",),
                              ar_strategy=strat)
            # Replicated input: every device holds the FULL msg_bytes
            # partial, exactly like a TP decode partial sum — and exactly
            # how the runtime dispatcher (_resolve_auto) keys the lookup.
            f = jax.jit(shard_map(
                lambda v: tp_all_reduce(v, ctx, scatter_dim=-1),
                mesh=mesh, in_specs=P(), out_specs=P(), check_vma=False))
            us = timeit(lambda: jax.block_until_ready(f(x)),
                        warmup=2, iters=5)
            measured[strat] = us
            # Record under every dtype the dispatcher queries: the byte
            # bucket already encodes the size, and what we measure here is
            # collective *structure*, which is dtype-agnostic — without
            # this, bf16 decode lookups (AutoTuner.choose's default) would
            # miss every measured entry.
            for dt in ("float32", "bfloat16", "float16"):
                tuner.record(msg_bytes, fast_n, slow_n, dt, strat,
                             us * 1e-6)
            grid.append({"msg_bytes": msg_bytes, "strategy": strat,
                         "us": us})
            emit(f"sweep/allreduce_{msg_bytes // KB}KB_{strat}", us,
                 f"devices=8;fast={fast_n};slow={slow_n}")
        analytic = tuner.choose(msg_bytes, fast_n, slow_n,
                                "float32").strategy
        best = min(measured, key=measured.get)
        regret = measured[analytic] / measured[best] - 1.0
        picks.append({"msg_bytes": msg_bytes, "analytic_pick": analytic,
                      "measured_best": best,
                      "analytic_us": measured[analytic],
                      "best_us": measured[best],
                      "regret": regret})
        emit(f"sweep/pick_{msg_bytes // KB}KB", measured[analytic],
             f"analytic={analytic};best={best};regret={regret:.3f}")

        # -- RS+AG column: the sequence-parallel decomposition ------------
        # Same residual message, issued as tp_reduce_scatter (ending the
        # row-parallel projection) + deferred tp_all_gather.  Latency is
        # measured with the shipped hier_rd slow phase; per-collective
        # wire bytes are read from the lowered HLO against the fused
        # single-collective (flat) all-reduce — the decomposition halves
        # what each collective moves (DESIGN.md §10).
        ctx_flat = ParallelCtx(tp_fast=("model",), tp_slow=("pod",),
                               ar_strategy="flat")
        ctx_rd = ctx_flat.replace(ar_strategy="hier_rd")
        f_fused = _shmap(lambda v: tp_all_reduce(v, ctx_flat,
                                                 scatter_dim=-1))
        def sp_pair(v, ctx=ctx_rd):
            return tp_all_gather(tp_reduce_scatter(v, ctx, dim=0), ctx,
                                 dim=0)
        f_sp = _shmap(sp_pair)
        f_sp_flat = _shmap(lambda v: sp_pair(v, ctx_flat))
        rs_ag_us = timeit(lambda: jax.block_until_ready(f_sp(x)),
                          warmup=2, iters=5)
        fused_pc, _ = _per_coll_wire(f_fused, x)
        sp_pc, sp_n = _per_coll_wire(f_sp_flat, x)
        auto_sp = tuner.choose_sp(msg_bytes, fast_n, slow_n, "float32")
        sp_rows.append({
            "msg_bytes": msg_bytes,
            "rs_ag_us": rs_ag_us,
            "fused_flat_us": measured["flat"],
            "auto_sp": auto_sp,
            "fused_pick": analytic,
            "fused_per_coll_wire_bytes": fused_pc,
            "rs_ag_per_coll_wire_bytes": sp_pc,
            "rs_ag_collectives": sp_n,
            "per_coll_ratio": sp_pc / fused_pc,
        })
        emit(f"sweep/rs_ag_{msg_bytes // KB}KB", rs_ag_us,
             f"auto_sp={auto_sp};per_coll_ratio={sp_pc / fused_pc:.3f}")

        # -- quantized-wire column: int8/int4 compressed all-reduce -------
        # The wire accounting runs against the bf16 payload (what decode
        # actually ships): a bf16 tensor of exactly msg_bytes through the
        # fp hierarchical-RD path vs the quantized one.  Wire bytes come
        # from the lowered HLO (packed int payload + bf16 group scales),
        # so the reduction factor is deterministic on any runner; the
        # measured latencies are recorded under the tuner's "auto"
        # namespace but only the analytic level is gated (CPU emulation
        # pays pack/unpack compute without real wire savings).
        xb = jnp.asarray(
            np.random.default_rng(1).standard_normal(msg_bytes // 2),
            jnp.bfloat16)
        q_wire = {}
        q_us = {}
        for quant in ("none", "int8", "int4"):
            ctx_q = ctx_rd.replace(ar_quant=quant)
            f_q = _shmap(lambda v, c=ctx_q: tp_all_reduce(v, c,
                                                          scatter_dim=-1))
            st = collective_bytes(f_q.lower(xb).as_text(dialect="hlo"),
                                  8, 2)
            assert st.count > 0
            q_wire[quant] = (st.wire_ici_bytes + st.wire_dcn_bytes,
                             st.count)
            q_us[quant] = timeit(lambda: jax.block_until_ready(f_q(xb)),
                                 warmup=2, iters=5)
            tuner.record(msg_bytes, fast_n, slow_n, "bfloat16", "hier_rd",
                         q_us[quant] * 1e-6, quant=quant, policy="auto")
        auto_q = autotune.analytic_quant_choice(
            msg_bytes, fast_n, slow_n, cm.TPU_V5E, "auto").quant
        for quant in ("int8", "int4"):
            red = q_wire["none"][0] / q_wire[quant][0]
            quant_rows.append({
                "msg_bytes": msg_bytes,
                "quant": quant,
                "wire_reduction": red,
                "q_wire_bytes": q_wire[quant][0],
                "fp_wire_bytes": q_wire["none"][0],
                "q_collectives": q_wire[quant][1],
                "q_us": q_us[quant],
                "fp_us": q_us["none"],
                "auto_bits": {"none": 0, "int8": 8, "int4": 4}[auto_q],
            })
            emit(f"sweep/quant_{msg_bytes // KB}KB_{quant}", q_us[quant],
                 f"wire_reduction={red:.2f}x;auto={auto_q}")
    # acceptance: each SP collective carries <= half the fused AR's wire
    # bytes, and the dispatcher splits the regimes — SP at prefill-sized
    # messages, fused hierarchical-RD at decode-sized ones.
    assert all(r["per_coll_ratio"] <= 0.5 + 1e-6 for r in sp_rows), sp_rows
    assert not sp_rows[0]["auto_sp"] and \
        sp_rows[0]["fused_pick"] == "hier_rd", sp_rows[0]
    assert all(r["auto_sp"] for r in sp_rows
               if r["msg_bytes"] >= 1 * MB), sp_rows
    # acceptance (quantized wire): the compressed payload beats the bf16
    # fp wire by >= 1.9x (int8) / 3.5x (int4) in the paper's 128KB-2MB
    # contended window (exact factors 1.97x / 3.76x: packed ints + bf16
    # group scales at GROUP_CAP granularity), and the deterministic
    # analytic ar_quant="auto" dispatch quantizes >= 1 bandwidth-bound
    # bucket while leaving decode-sized messages on the fp path.
    floors = {"int8": 1.9, "int4": 3.5}
    for r in quant_rows:
        if 128 * KB <= r["msg_bytes"] <= 2 * MB:
            assert r["wire_reduction"] >= floors[r["quant"]], r
    assert any(r["auto_bits"] for r in quant_rows), quant_rows
    assert all(r["auto_bits"] == 0 for r in quant_rows
               if r["msg_bytes"] <= 64 * KB), quant_rows
    # refine: measured winners overwrite the analytic seeds
    tuner.refine()
    doc = {
        "device_count": 8,
        "mesh": [2, 4],
        "topology": {"fast": fast_n, "slow": slow_n},
        "dtype": "float32",
        "note": ("latencies are CPU host-device emulation - relative "
                 "ordering tracks collective structure (message count / "
                 "payload), not real ICI/DCN wire time"),
        "grid": grid,
        "picks": picks,
        "sp_rows": sp_rows,
        "quant_rows": quant_rows,
        "tuned_table": tuner.to_json(),
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    emit("sweep/json_written", float(len(grid)), out_path)
    return doc


def run():
    model_sweep()
    tpu_projection()
    hlo_structural()


def main(argv=None):
    import argparse
    import os
    import sys

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sweep", action="store_true",
                    help="measure the strategy x message-size grid on 8 "
                         "host devices and write BENCH_allreduce.json")
    ap.add_argument("--out", default="BENCH_allreduce.json")
    args = ap.parse_args(argv)
    if not args.sweep:
        run()
        return
    if "jax" in sys.modules:
        raise SystemExit("--sweep must configure devices before jax "
                         "initializes; run as a fresh process")
    flags = os.environ.get("XLA_FLAGS", "")
    if "host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = \
            (flags + " --xla_force_host_platform_device_count=8").strip()
    measured_sweep(args.out)


if __name__ == "__main__":
    main()
