"""Paper Figs. 1/2 (+ Fig. 11): strong scaling of TP vs HP for Llama-3.1
70B/405B across prefill-heavy and decode-heavy batched workloads, via the
event-driven simulator with the paper's Perlmutter constants."""
from __future__ import annotations

from .common import emit

WORKLOADS = {
    "prefill_heavy": (2363, 128),
    "decode_heavy": (1426, 3072),
}


def run():
    from repro.inference.simulator import simulate_batch_latency, A100
    from repro.core.comm_model import PERLMUTTER
    from repro.configs.llama3_paper import LLAMA31_70B, LLAMA31_405B

    for model, gpus in ((LLAMA31_70B, (4, 8, 16, 32)),
                        (LLAMA31_405B, (16, 32, 64, 128))):
        for wl, (pl, dl) in WORKLOADS.items():
            for npr in (8, 32):
                for n in gpus:
                    for scheme in ("tp", "hp"):
                        t, bd = simulate_batch_latency(
                            model, A100, PERLMUTTER, n, scheme=scheme,
                            ar_algo="nccl", prompt_len=pl, decode_len=dl,
                            n_prompts=npr)
                        emit(f"fig1-2/{model.name}/{wl}/P{npr}/"
                             f"{scheme}{n}", t * 1e6,
                             f"matmul_s={bd.matmul:.2f};"
                             f"comm_s={bd.comm:.2f};idle_s={bd.idle:.2f}")


if __name__ == "__main__":
    run()
