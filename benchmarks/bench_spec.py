"""Speculative-decoding benchmark: acceptance rate x k x AR message size.

For each speculation length k and drafter, a BurstGPT-style trace replays
through the continuous batcher in spec mode and we record the acceptance
rate, accepted tokens per verify pass, the engine-step reduction against
the plain sequential-decode baseline (deterministic logical steps, so the
numbers are CI-stable), and the per-layer all-reduce message widening —
one verify pass carries a (k+1)-token activation where sequential decode
carried one token, i.e. the workload-side shift of the paper's per-token
AR bottleneck into the message-size region where the autotuner's strategy
choice matters (the log2 bucket column is exactly the dispatch key the
``ar_table`` resolves on).

Every spec cell is asserted bitwise-equal to the plain greedy streams
before its row is recorded — this benchmark cannot silently trade
correctness for speed.

    python -m benchmarks.bench_spec --sweep    # writes BENCH_spec.json
    python -m benchmarks.bench_spec            # quick smoke rows
"""
from __future__ import annotations

import json

import numpy as np

from .common import emit

S_MAX = 128
N_REQ = 12
SLOTS = 4
MEAN_OUT = 14


def _make_reqs(vocab, seed=3):
    from repro.inference.scheduler import make_trace
    return make_trace(N_REQ, mean_in=12, mean_out=MEAN_OUT, rate=3.0,
                      vocab=vocab, seed=seed)


def _run(ap, params, vocab, *, drafter=None, **kw):
    from repro.inference.spec import ReplicaSpec, build_replica
    sched = build_replica(
        ReplicaSpec(arch="llama3.2-1b", slots=SLOTS, s_max=S_MAX,
                    block_size=8, **kw),
        ap=ap, params=params, drafter=drafter)
    done = sched.run(_make_reqs(vocab))
    assert all(r.output is not None for r in done), "dropped requests"
    return {r.rid: r.output for r in done}, sched.metrics(done)


def sweep(out_path: str = "BENCH_spec.json"):
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke
    from repro.core.autotune import _bucket
    from repro.inference.speculative import ReplayDrafter
    from repro.models.transformer import make_plan, init_params

    cfg = get_smoke("llama3.2-1b")
    itemsize = jnp.dtype(cfg.dtype).itemsize
    ap = make_plan(cfg, 1)
    params = init_params(jax.random.PRNGKey(0), ap)

    plain, m0 = _run(ap, params, cfg.vocab_size)
    streams = {tuple(int(t) for t in r.prompt): list(plain[r.rid])
               for r in _make_reqs(cfg.vocab_size)}
    decode_bytes = SLOTS * 1 * cfg.d_model * itemsize

    rows = []
    for k in (2, 4, 8):
        for drafter_name in ("ngram", "replay"):
            kw = dict(spec_mode=drafter_name, spec_k=k)
            if drafter_name == "replay":
                kw["drafter"] = ReplayDrafter(streams)
            got, m = _run(ap, params, cfg.vocab_size, **kw)
            for rid in plain:
                assert np.array_equal(plain[rid], got[rid]), \
                    (k, drafter_name, rid)
            verify_bytes = SLOTS * (k + 1) * cfg.d_model * itemsize
            row = {
                "k": k, "drafter": drafter_name,
                "baseline_steps": m0.steps,
                "step_ratio": m.steps / m0.steps,
                "ar_msg_bytes_decode": decode_bytes,
                "ar_msg_bytes_verify": verify_bytes,
                "ar_bucket_decode": _bucket(decode_bytes),
                "ar_bucket_verify": _bucket(verify_bytes),
                **m.to_dict(),
            }
            rows.append(row)
            emit(f"spec/k{k}_{drafter_name}", m.acceptance_rate,
                 f"steps={m.steps}/{m0.steps};"
                 f"acc_per_step={m.accepted_tokens_per_step:.2f};"
                 f"ar_bytes={decode_bytes}->{verify_bytes}")

    best = min((r for r in rows if r["drafter"] == "replay"),
               key=lambda r: r["step_ratio"])
    summary = {
        "baseline_steps": m0.steps,
        "best_step_ratio": best["step_ratio"],
        "best_k": best["k"],
        "ngram_acceptance_by_k": {str(r["k"]): r["acceptance_rate"]
                                  for r in rows
                                  if r["drafter"] == "ngram"},
        "replay_acceptance_by_k": {str(r["k"]): r["acceptance_rate"]
                                   for r in rows
                                   if r["drafter"] == "replay"},
        "ar_bucket_shift": {str(r["k"]): [r["ar_bucket_decode"],
                                          r["ar_bucket_verify"]]
                            for r in rows if r["drafter"] == "replay"},
    }
    with open(out_path, "w") as f:
        json.dump({"arch": "llama3.2-1b(smoke)", "s_max": S_MAX,
                   "slots": SLOTS, "n_requests": N_REQ,
                   "summary": summary, "rows": rows},
                  f, indent=2, sort_keys=True, default=float)
    emit("spec/json_written", float(len(rows)), out_path)
    assert best["step_ratio"] < 0.6, \
        "oracle-drafted spec decode should cut sequential steps sharply"
    for r in rows:
        if r["drafter"] == "replay":
            # not 1.0: drafts padded past a short request's stream end are
            # rejected, and that tail grows with k
            assert r["acceptance_rate"] > 0.7, r
    return rows


def run():
    import jax
    from repro.configs import get_smoke
    from repro.models.transformer import make_plan, init_params
    cfg = get_smoke("llama3.2-1b")
    ap = make_plan(cfg, 1)
    params = init_params(jax.random.PRNGKey(0), ap)
    plain, m0 = _run(ap, params, cfg.vocab_size)
    got, m = _run(ap, params, cfg.vocab_size, spec_mode="ngram", spec_k=4)
    for rid in plain:
        assert np.array_equal(plain[rid], got[rid]), rid
    emit("spec/smoke_ngram_k4", m.acceptance_rate,
         f"steps={m.steps}/{m0.steps};hit={m.drafter_hit_rate:.2f}")


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sweep", action="store_true",
                    help="full k x drafter grid (BENCH_spec.json)")
    ap.add_argument("--out", default="BENCH_spec.json")
    args = ap.parse_args(argv)
    if args.sweep:
        sweep(args.out)
    else:
        run()


if __name__ == "__main__":
    main()
