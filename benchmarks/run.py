"""Benchmark harness — one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run``  prints ``name,us_per_call,
derived`` CSV rows.  Multi-device benchmark parts (HLO-structural collective
measurements, real sharded-integration checks) run in a subprocess with
simulated host devices so this process keeps the single-device view.

Map to the paper:
    bench_scaling    -> Figs. 1, 2, 11 (strong scaling TP vs HP)
    bench_breakdown  -> Figs. 3, 8 (+ straggler sensitivity)
    bench_gemm       -> Table 4 (M-halving vs K-halving)
    bench_allreduce  -> Figs. 4, 6, 14, 15 (algorithm comparison)
    bench_chunks     -> Table 5 (chunk-size sensitivity)
    bench_e2e        -> Figs. 7, 16 (end-to-end NVRAR speedup)
    bench_trace      -> Figs. 9, 18 (trace serving throughput)
    bench_moe        -> Fig. 10 (MoE TP x EP)
    roofline_table   -> EXPERIMENTS.md §Roofline summary
"""
from __future__ import annotations

import os
import subprocess
import sys


def _run_subprocess_dist():
    """Re-run the device-hungry benchmark parts with 8 simulated devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    code = ("from benchmarks.bench_e2e import real_integration; "
            "from benchmarks.bench_moe import real_moe_integration; "
            "from benchmarks.bench_chunks import kernel_structure; "
            "real_integration(); real_moe_integration(); "
            "kernel_structure()")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=1800)
    sys.stdout.write(proc.stdout)
    if proc.returncode != 0:
        print(f"dist-bench subprocess failed:\n{proc.stderr[-2000:]}",
              file=sys.stderr)
        return False
    return True


def main() -> None:
    from . import (bench_scaling, bench_breakdown, bench_gemm,
                   bench_allreduce, bench_chunks, bench_e2e, bench_trace,
                   bench_moe, roofline_table)
    print("name,us_per_call,derived")
    bench_scaling.run()
    bench_breakdown.run()
    bench_gemm.run()
    bench_allreduce.model_sweep()
    bench_allreduce.tpu_projection()
    bench_chunks.modelled_sweep()
    bench_e2e.simulated()
    bench_trace.simulated()
    bench_trace.real_scheduler()
    bench_moe.simulated()
    ok = _run_subprocess_dist()
    roofline_table.run()
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
