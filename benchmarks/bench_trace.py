"""Paper Figs. 9 & 18: trace-based serving throughput (BurstGPT-like and
decode-heavy traces) for NCCL-TP vs NVRAR-TP vs HP under two concurrency
caps, via the event-driven serving simulator; plus a REAL continuous-batching
replay on the tiny engine (scheduler correctness: no dropped requests)."""
from __future__ import annotations

import numpy as np

from .common import emit


def simulated():
    from repro.inference.simulator import simulate_trace, A100
    from repro.core.comm_model import PERLMUTTER
    from repro.configs.llama3_paper import LLAMA31_70B as M70

    rng = np.random.default_rng(0)
    n = 1000

    def lengths(mean_in, mean_out):
        li = np.maximum(2, rng.lognormal(np.log(mean_in), 0.6, n)).astype(int)
        lo = np.maximum(1, rng.lognormal(np.log(mean_out), 0.6, n)).astype(int)
        return li, lo

    # BurstGPT-like (Fig. 9) and decode-heavy (Fig. 18) traces
    for trace, (mi, mo) in (("burstgpt", (600, 250)),
                            ("decode_heavy", (1024, 4096))):
        li, lo = lengths(mi, mo)
        shape = 1.0 / 2.0  # burstiness 2.0 (gamma)
        arr = np.cumsum(rng.gamma(shape, scale=1.0 / (10.0 * shape), size=n))
        for conc in (32, 256):
            results = {}
            for label, scheme, algo in (("nccl_tp", "tp", "nccl"),
                                        ("nvrar_tp", "tp", "nvrar"),
                                        ("hp", "hp", "nccl")):
                out = simulate_trace(M70, A100, PERLMUTTER, 16,
                                     scheme=scheme, ar_algo=algo,
                                     arrivals=arr, in_lens=li, out_lens=lo,
                                     concurrency=conc)
                results[label] = out["throughput_tok_s"]
                emit(f"fig9-18/{trace}/C{conc}/{label}",
                     out["makespan_s"] * 1e6,
                     f"throughput_tok_s={out['throughput_tok_s']:.1f}")
            emit(f"fig9-18/{trace}/C{conc}/nvrar_vs_nccl_speedup",
                 results["nvrar_tp"] / max(results["nccl_tp"], 1e-9),
                 f"vs_hp={results['nvrar_tp']/max(results['hp'],1e-9):.2f}x")


def real_scheduler():
    import jax
    from repro.configs import get_smoke
    from repro.models.transformer import make_plan, init_params
    from repro.inference.scheduler import make_trace
    from repro.inference.spec import ReplicaSpec, build_replica
    cfg = get_smoke("llama3.2-1b")
    ap = make_plan(cfg, 1)
    params = init_params(jax.random.PRNGKey(0), ap)
    sched = build_replica(ReplicaSpec(arch="llama3.2-1b", slots=4,
                                      s_max=96), ap=ap, params=params)
    reqs = make_trace(10, mean_in=12, mean_out=8, rate=3.0,
                      vocab=cfg.vocab_size, seed=1)
    done = sched.run(reqs)
    completed = sum(r.output is not None for r in done)
    total = sum(len(r.output) for r in done if r.output is not None)
    emit("fig9/real_scheduler_completed", completed,
         f"requests=10;tokens={total}")
    assert completed == 10


def run():
    simulated()
    real_scheduler()


if __name__ == "__main__":
    run()
