"""Shared benchmark utilities: CSV emission in the harness format."""
from __future__ import annotations

import sys
import time
from typing import Callable, List


ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.3f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time in microseconds."""
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


__all__ = ["emit", "timeit", "ROWS"]
