"""Paper Fig. 10: MoE serving (Qwen3-235B-A22B-like) — NVRAR accelerates the
TP all-reduce of the non-MoE layers, orthogonal to EP.  Simulated trace
throughput for TP16-EP16 with NCCL vs NVRAR vs PP, plus a REAL numerical
check that the qwen3-moe smoke model produces identical generations under
flat vs hierarchical AR (EP + hierarchical TP compose correctly)."""
from __future__ import annotations

import dataclasses

import numpy as np

from .common import emit


def simulated():
    from repro.inference.simulator import simulate_trace, A100
    from repro.core.comm_model import PERLMUTTER
    from repro.models.common import ModelConfig

    qwen3_235b = ModelConfig(
        name="qwen3-235b-a22b", family="moe",
        n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
        d_ff=1536, vocab_size=151936, n_experts=128, top_k=8,
        d_ff_expert=1536)

    rng = np.random.default_rng(0)
    n = 500
    li = np.maximum(2, rng.lognormal(np.log(600), 0.6, n)).astype(int)
    lo = np.maximum(1, rng.lognormal(np.log(250), 0.6, n)).astype(int)
    arr = np.cumsum(rng.gamma(0.5, scale=1.0 / (10.0 * 0.5), size=n))
    for conc in (32, 128):
        res = {}
        for label, scheme, algo in (("tp16_ep16_nccl", "tp", "nccl"),
                                    ("tp16_ep16_nvrar", "tp", "nvrar"),
                                    ("pp4", "hp", "nccl")):
            out = simulate_trace(qwen3_235b, A100, PERLMUTTER, 16,
                                 scheme=scheme, ar_algo=algo,
                                 arrivals=arr, in_lens=li, out_lens=lo,
                                 concurrency=conc)
            res[label] = out["throughput_tok_s"]
            emit(f"fig10/C{conc}/{label}", out["makespan_s"] * 1e6,
                 f"throughput_tok_s={out['throughput_tok_s']:.1f}")
        emit(f"fig10/C{conc}/nvrar_gain",
             res["tp16_ep16_nvrar"] / max(res["tp16_ep16_nccl"], 1e-9),
             "moe_tp_ar_acceleration")


def real_moe_integration():
    import jax
    if len(jax.devices()) < 8:
        emit("fig10/real_moe", 0.0, "skipped=needs_8_devices")
        return
    import jax.numpy as jnp
    from repro.core.compat import AxisType, make_mesh
    from repro.core.pcontext import ParallelCtx
    from repro.models import ModelConfig, make_plan, init_params
    from repro.parallel.steps import build_decode_step, build_prefill
    cfg = ModelConfig(name="moe-tiny", family="moe", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, head_dim=16, d_ff=32,
                      vocab_size=96, n_experts=8, top_k=2, d_ff_expert=32,
                      capacity_factor=8.0, dtype=jnp.float32)
    mesh = make_mesh((2, 4), ("pod", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    toks = {}
    for strat in ("flat", "hier_rd"):
        ctx = ParallelCtx(tp_fast=("model",), tp_slow=("pod",),
                          ep=("model",), ar_strategy=strat)
        ap = make_plan(cfg, 8)
        params = init_params(jax.random.PRNGKey(0), ap)
        pre = build_prefill(ap, ctx, mesh, s_max=24)
        dec = build_decode_step(ap, ctx, mesh)
        prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, 96)
        nxt, cache = jax.jit(pre.fn)(params, prompts)
        seq = [np.asarray(nxt)]
        pos = jnp.full((4,), 8, jnp.int32)
        for i in range(4):
            nxt, cache = dec.jit()(params, cache, nxt, pos + i)
            seq.append(np.asarray(nxt))
        toks[strat] = np.stack(seq)
    same = bool(np.array_equal(toks["flat"], toks["hier_rd"]))
    emit("fig10/real_moe_tokens_match", float(same), "ep_x_hier_tp")
    assert same


def run():
    simulated()
    real_moe_integration()


if __name__ == "__main__":
    run()
