"""Paper Figs. 3 & 8: per-phase time breakdowns.

Fig. 3: TP vs HP breakdown (matmul / other / comm / idle) at 8 and 16 GPUs
for the 70B model on both workloads.
Fig. 8: NVRAR vs NCCL breakdown for decode-heavy TP on 16 GPUs.
"""
from __future__ import annotations

from .common import emit


def run():
    from repro.inference.simulator import simulate_batch_latency, A100
    from repro.core.comm_model import PERLMUTTER
    from repro.configs.llama3_paper import LLAMA31_70B as M70

    for wl, (pl, dl) in (("prefill_heavy", (2363, 128)),
                         ("decode_heavy", (1426, 3072))):
        for n in (8, 16):
            for scheme in ("tp", "hp"):
                t, bd = simulate_batch_latency(
                    M70, A100, PERLMUTTER, n, scheme=scheme,
                    ar_algo="nccl", prompt_len=pl, decode_len=dl,
                    n_prompts=8)
                emit(f"fig3/{wl}/{scheme}{n}", t * 1e6,
                     f"matmul={bd.matmul:.2f};other={bd.other:.2f};"
                     f"comm={bd.comm:.2f};idle={bd.idle:.2f}")

    for npr in (8, 32):
        for algo in ("nccl", "nvrar"):
            t, bd = simulate_batch_latency(
                M70, A100, PERLMUTTER, 16, scheme="tp", ar_algo=algo,
                prompt_len=1426, decode_len=3072, n_prompts=npr)
            emit(f"fig8/decode_heavy/P{npr}/{algo}", t * 1e6,
                 f"matmul={bd.matmul:.2f};other={bd.other:.2f};"
                 f"comm={bd.comm:.2f}")

    # straggler sensitivity (StragglAR-adjacent; ring pays per-hop)
    for delay_us in (0, 5, 20):
        for algo in ("ring", "nvrar"):
            t, bd = simulate_batch_latency(
                M70, A100, PERLMUTTER, 16, scheme="tp", ar_algo=algo,
                prompt_len=1426, decode_len=3072, n_prompts=8,
                straggler_delay=delay_us * 1e-6)
            emit(f"straggler/{algo}/delay{delay_us}us", t * 1e6,
                 f"comm_s={bd.comm:.2f}")


if __name__ == "__main__":
    run()
