"""Paper Table 4: Prefill-GEMM vs Decode-GEMM under M-halving (HP) and
K-halving (TP).

Two channels: (1) real CPU matmul timings at 1/8-scaled shapes (the tile
effect is hardware-universal: BLAS kernels also stop scaling below their M
tile); (2) the simulator's tile-floor model at the paper's exact shapes for
A100 and for the v5e target.
"""
from __future__ import annotations

import numpy as np

from .common import emit, timeit


def measured_cpu(scale: int = 16):
    import jax
    import jax.numpy as jnp
    shapes = {
        "prefill_gemm": (32768 // scale, 8192 // scale, 57344 // scale),
        "decode_gemm": (32, 8192 // scale, 57344 // scale),
    }
    f = jax.jit(lambda a, b: a @ b)
    for name, (m, n, k) in shapes.items():
        rng = np.random.default_rng(0)
        for variant, (mm, kk) in (("baseline", (m, k)), ("HP_M/2", (m // 2, k)),
                                  ("TP_K/2", (m, k // 2))):
            mm = max(mm, 1)
            a = jnp.asarray(rng.standard_normal((mm, kk)), jnp.float32)
            b = jnp.asarray(rng.standard_normal((kk, n)), jnp.float32)
            us = timeit(lambda a=a, b=b: jax.block_until_ready(f(a, b)),
                        warmup=1, iters=2)
            emit(f"table4/cpu/{name}/{variant}", us,
                 f"M={mm};N={n};K={kk}")


def modeled(chip_name: str):
    from repro.inference.simulator import A100, V5E
    chip = {"a100": A100, "v5e": V5E}[chip_name]
    eff = chip.flops_bf16 * chip.efficiency
    for name, (m, n, k) in {
        "prefill_gemm": (32768, 8192, 57344),
        "decode_gemm": (32, 8192, 57344),
    }.items():
        base = None
        for variant, (mm, kk) in (("baseline", (m, k)), ("HP_M/2", (m // 2, k)),
                                  ("TP_K/2", (m, k // 2))):
            m_eff = max(mm, chip.gemm_tile_m)
            flops = 2.0 * m_eff * n * kk
            bytes_ = 2.0 * (mm * kk + kk * n + mm * n)
            t = max(flops / eff, bytes_ / chip.hbm_bw)
            if base is None:
                base = t
            emit(f"table4/model_{chip_name}/{name}/{variant}", t * 1e6,
                 f"speedup_vs_base={base/t:.2f}x")


def run():
    measured_cpu()
    modeled("a100")
    modeled("v5e")


if __name__ == "__main__":
    run()
