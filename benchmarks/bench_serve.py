"""Trace-serving benchmark over the unified serving stack: request rate x
slot count x KV-cache block size.

For each cell a BurstGPT-style trace replays through the continuous
batcher and we record throughput, TTFT/TPOT percentiles, peak KV
footprint, cache utilization and preemption count — the evidence that the
paged (block-table) layout sustains the same trace at a fraction of the
dense ``(slots, s_max)`` reservation (and keeps serving, via preemption,
when given a pool smaller than the dense layout could even express).

    python -m benchmarks.bench_serve --sweep      # writes BENCH_serve.json
    python -m benchmarks.bench_serve              # quick smoke rows
"""
from __future__ import annotations

import json

import numpy as np

from .common import emit

S_MAX = 128
N_REQ = 16


def _cell(ap, params, vocab, *, rate, slots, block_size, n_blocks=None,
          seed=1):
    import jax  # noqa: F401  (env sanity)
    from repro.inference.scheduler import make_trace
    from repro.inference.spec import ReplicaSpec, build_replica
    sched = build_replica(
        ReplicaSpec(arch="llama3.2-1b", slots=slots, s_max=S_MAX,
                    block_size=block_size, n_blocks=n_blocks),
        ap=ap, params=params)
    reqs = make_trace(N_REQ, mean_in=12, mean_out=10, rate=rate,
                      vocab=vocab, seed=seed)
    done = sched.run(reqs)
    assert all(r.output is not None for r in done), "dropped requests"
    m = sched.metrics(done)
    row = {"rate": rate, "slots": slots, "block_size": block_size,
           "n_blocks": n_blocks, **m.to_dict()}
    return row, m


def _sp_operating_point(d_model: int = 4096, chunk: int = 4096,
                        itemsize: int = 2, fast: int = 16, slow: int = 2):
    """Sequence-parallel prefill operating point at a production-ish shape
    (DESIGN.md §10): per-collective comm-bytes reduction of the RS+AG
    decomposition vs the fused per-residual all-reduce, the activation
    footprint between collectives (what actually caps the admit chunk),
    and the autotuner's SP-vs-fused pick for that message."""
    from repro.core import autotune
    from repro.core.comm_model import TPU_V5E
    msg = chunk * d_model * itemsize
    g = fast
    fused_wire = 2.0 * (g * slow - 1) / (g * slow) * msg  # one flat AR
    sp_wire = (g - 1) / g * msg                           # RS or AG half
    act_fused = chunk * d_model * itemsize                # replicated
    act_sp = act_fused // g                               # sequence shard
    return {
        "d_model": d_model, "prefill_chunk_tokens": chunk,
        "residual_msg_bytes": msg,
        "fused_ar_wire_bytes_per_coll": fused_wire,
        "sp_wire_bytes_per_coll": sp_wire,
        "per_coll_bytes_reduction": fused_wire / sp_wire,
        "activation_bytes_per_chunk_fused": act_fused,
        "activation_bytes_per_chunk_sp": act_sp,
        # at a fixed activation budget, sharded residuals admit a chunk
        # `fast`x larger than the replicated layout
        "max_admit_chunk_gain": g,
        "auto_dispatch_sp": bool(
            autotune.AutoTuner(TPU_V5E).choose_sp(msg, fast, slow)),
    }


def sweep(out_path: str = "BENCH_serve.json"):
    import jax
    from repro.configs import get_smoke
    from repro.models.transformer import make_plan, init_params

    cfg = get_smoke("llama3.2-1b")
    ap = make_plan(cfg, 1)
    params = init_params(jax.random.PRNGKey(0), ap)
    rows = []
    for rate in (1.0, 3.0, 6.0):
        for slots in (2, 4):
            for bs in (0, 8, 32):
                row, m = _cell(ap, params, cfg.vocab_size, rate=rate,
                               slots=slots, block_size=bs)
                rows.append(row)
                layout = f"bs{bs}" if bs else "dense"
                emit(f"serve/r{rate:g}_s{slots}_{layout}",
                     m.ttft_steps_p50,
                     f"tok_s={m.throughput_tok_s:.0f};"
                     f"peak_kv={m.peak_kv_tokens};"
                     f"tpot_p99={m.tpot_steps_p99:.2f}")

    # tight-pool cells: a pool the dense layout could not even allocate
    # (fewer tokens than slots*s_max) still completes the trace via
    # preemption — the admissible-rate headroom paging buys.
    for slots, n_blocks in ((4, 33), (4, 17)):
        row, m = _cell(ap, params, cfg.vocab_size, rate=3.0, slots=slots,
                       block_size=8, n_blocks=n_blocks)
        row["tight_pool"] = True
        rows.append(row)
        emit(f"serve/tight_s{slots}_nb{n_blocks}", m.ttft_steps_p50,
             f"tok_s={m.throughput_tok_s:.0f};preempt={m.preemptions};"
             f"pool_tokens={(n_blocks - 1) * 8}")

    # decode-heavy overcommit cell: three long decodes against a pool that
    # holds ~1.5 of them -> preemption keeps the trace completing
    from repro.inference.scheduler import Request
    from repro.inference.spec import ReplicaSpec, build_replica
    rng = np.random.default_rng(5)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                               16).astype(np.int32),
                    max_new=48, arrival_s=0.0) for i in range(3)]
    sched = build_replica(
        ReplicaSpec(arch="llama3.2-1b", slots=3, s_max=S_MAX,
                    block_size=8, n_blocks=17), ap=ap, params=params)
    done = sched.run(reqs)
    assert all(r.output is not None for r in done)
    m = sched.metrics(done)
    row = {"rate": 0.0, "slots": 3, "block_size": 8, "n_blocks": 17,
           "tight_pool": True, "decode_heavy": True, **m.to_dict()}
    rows.append(row)
    emit("serve/overcommit_decode_heavy", m.ttft_steps_p50,
         f"tok_s={m.throughput_tok_s:.0f};preempt={m.preemptions};"
         f"pool_tokens={16 * 8}")
    assert m.preemptions > 0, "overcommit cell should preempt"

    # headline comparison at the reference cell (rate 3, 4 slots)
    ref = {(r["block_size"]): r for r in rows
           if r["rate"] == 3.0 and r["slots"] == 4
           and not r.get("tight_pool")}
    dense, paged = ref[0], ref[8]
    summary = {
        "dense_peak_kv_tokens": dense["peak_kv_tokens"],
        "paged_peak_kv_tokens": paged["peak_kv_tokens"],
        "kv_savings_ratio": dense["peak_kv_tokens"]
        / max(paged["peak_kv_tokens"], 1),
        "same_throughput": abs(dense["total_new_tokens"]
                               - paged["total_new_tokens"]) == 0,
        "dense_ttft_p50_steps": dense["ttft_steps_p50"],
        "paged_ttft_p50_steps": paged["ttft_steps_p50"],
        "seq_parallel": _sp_operating_point(),
    }
    with open(out_path, "w") as f:
        json.dump({"arch": "llama3.2-1b(smoke)", "s_max": S_MAX,
                   "n_requests": N_REQ, "summary": summary, "rows": rows},
                  f, indent=2, sort_keys=True, default=float)
    emit("serve/json_written", float(len(rows)), out_path)
    assert summary["kv_savings_ratio"] > 1.0, \
        "paged layout should beat the dense reservation on this trace"
    return rows


def run():
    import jax
    from repro.configs import get_smoke
    from repro.models.transformer import make_plan, init_params
    cfg = get_smoke("llama3.2-1b")
    ap = make_plan(cfg, 1)
    params = init_params(jax.random.PRNGKey(0), ap)
    for bs in (0, 8):
        row, m = _cell(ap, params, cfg.vocab_size, rate=3.0, slots=4,
                       block_size=bs)
        emit(f"serve/smoke_{'paged' if bs else 'dense'}",
             m.ttft_steps_p50,
             f"tok_s={m.throughput_tok_s:.0f};peak_kv={m.peak_kv_tokens}")


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sweep", action="store_true",
                    help="full rate x slots x block-size grid "
                         "(BENCH_serve.json)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)
    if args.sweep:
        sweep(args.out)
    else:
        run()


if __name__ == "__main__":
    main()
